"""Gradient bucketing: fused, persistent, alignment-guaranteed buffers.

This is the TPU analogue of the paper's two memory techniques:

* **T1 (guaranteed huge pages)** — a model's gradient pytree has hundreds of
  small leaves; reducing each one separately pays per-collective launch and
  ring latency (p-1 hops) *per tensor*, exactly like per-4KB-page pinning
  overhead.  We fuse leaves into large fixed-size buckets (default 4 MiB —
  two 'huge pages') padded to the ring/codec/lane alignment the schedule
  *guarantees* to tile, so performance cannot regress based on parameter
  shapes (the paper: "guarantees are preferable to optimistic probabilistic
  statements").

* **T2 (persistent allocation, decoupled from the op)** — the layout plan is
  computed once per (treedef, shapes, dtypes) signature and cached; every
  subsequent step reuses it.  Inside ``jit`` the flatten/unflatten lower to
  pure data movement that XLA schedules around the collectives.

The bucketer operates on *local shards* (it runs inside ``shard_map``), so
fusing tensors with heterogeneous ``PartitionSpec``s is safe: concatenation
happens in each device's local address space, never resharding anything.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import padded_size

LANE_MULTIPLE = 128  # TPU lane width; keeps slices layout-friendly


@dataclass(frozen=True)
class BucketField:
    """Placement of one pytree leaf inside a bucket."""

    leaf: int          # index into the flattened pytree
    shape: tuple[int, ...]
    dtype: Any
    bucket: int
    offset: int        # element offset within the bucket
    size: int          # element count


@dataclass(frozen=True)
class BucketPlan:
    treedef: Any
    fields: tuple[BucketField, ...]
    bucket_sizes: tuple[int, ...]   # padded element counts per bucket
    bucket_dtype: Any
    pad_multiple: int

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_sizes)

    @property
    def total_elems(self) -> int:
        return int(sum(self.bucket_sizes))

    @property
    def used_elems(self) -> int:
        return int(sum(f.size for f in self.fields))

    @property
    def padding_waste(self) -> float:
        t = self.total_elems
        return 0.0 if t == 0 else 1.0 - self.used_elems / t


class GradientBucketer:
    """Greedy size-capped packer with a persistent plan cache.

    Leaves pack into buckets in pytree order until the next leaf would
    overflow ``bucket_bytes``; each bucket is then padded up to
    ``pad_multiple`` elements.  **Oversized-leaf invariant**: a leaf larger
    than ``bucket_bytes`` is *never split* — it becomes a singleton bucket
    of its own (padded) size, and the next leaf always starts a fresh
    bucket.  Leaves stay contiguous ranges of exactly one bucket, which the
    debucketize slicing, the reduce-scatter ownership layout, and the
    schedule's bucket-id indexing all rely on; ``bucket_bytes`` is a
    *target*, not a bound.
    """

    def __init__(self, bucket_bytes: int = 4 * 2**20,
                 pad_multiple: int = LANE_MULTIPLE,
                 bucket_dtype=jnp.float32):
        if bucket_bytes <= 0:
            raise ValueError("bucket_bytes must be positive")
        self.bucket_bytes = int(bucket_bytes)
        self.pad_multiple = int(np.lcm(pad_multiple, LANE_MULTIPLE))
        self.bucket_dtype = jnp.dtype(bucket_dtype)
        self._plans: dict[Any, BucketPlan] = {}

    # -- planning ----------------------------------------------------------

    def _signature(self, leaves: Sequence[jax.Array], treedef) -> Any:
        return (treedef, tuple((tuple(l.shape), jnp.dtype(l.dtype).name)
                               for l in leaves))

    def plan(self, tree) -> BucketPlan:
        leaves, treedef = jax.tree.flatten(tree)
        sig = self._signature(leaves, treedef)
        cached = self._plans.get(sig)
        if cached is not None:
            return cached

        cap = max(self.bucket_bytes // self.bucket_dtype.itemsize, 1)
        fields: list[BucketField] = []
        bucket_sizes: list[int] = []
        cur_bucket, cur_fill = -1, 0
        for i, leaf in enumerate(leaves):
            n = int(np.prod(leaf.shape)) if leaf.shape else 1
            if cur_bucket < 0 or cur_fill + n > cap:
                # close the previous bucket (pad) and open a fresh one;
                # oversized leaves get a dedicated bucket of their own size.
                if cur_bucket >= 0:
                    bucket_sizes[cur_bucket] = padded_size(cur_fill, self.pad_multiple)
                bucket_sizes.append(0)
                cur_bucket, cur_fill = len(bucket_sizes) - 1, 0
            fields.append(BucketField(i, tuple(leaf.shape), jnp.dtype(leaf.dtype),
                                      cur_bucket, cur_fill, n))
            cur_fill += n
        if cur_bucket >= 0:
            bucket_sizes[cur_bucket] = padded_size(cur_fill, self.pad_multiple)

        plan = BucketPlan(treedef, tuple(fields), tuple(bucket_sizes),
                          self.bucket_dtype, self.pad_multiple)
        self._plans[sig] = plan
        return plan

    # -- execution (runs inside jit / shard_map) ----------------------------

    def bucketize(self, tree, plan: BucketPlan | None = None) -> tuple[list[jax.Array], BucketPlan]:
        plan = plan or self.plan(tree)
        leaves = jax.tree.flatten(tree)[0]
        per_bucket: list[list[jax.Array]] = [[] for _ in plan.bucket_sizes]
        fill: list[int] = [0] * plan.n_buckets
        for f in plan.fields:
            per_bucket[f.bucket].append(
                leaves[f.leaf].reshape(-1).astype(plan.bucket_dtype))
            fill[f.bucket] += f.size
        buckets = []
        for b, parts in enumerate(per_bucket):
            pad = plan.bucket_sizes[b] - fill[b]
            if pad:
                parts.append(jnp.zeros((pad,), plan.bucket_dtype))
            buckets.append(jnp.concatenate(parts) if len(parts) > 1 else parts[0])
        return buckets, plan

    def debucketize(self, buckets: Sequence[jax.Array], plan: BucketPlan,
                    cast_to=None):
        """``cast_to`` overrides the per-field dtype (e.g. keep gathered
        FSDP weights in bf16 instead of re-materialising fp32)."""
        leaves: list[jax.Array | None] = [None] * len(plan.fields)
        for f in plan.fields:
            flat = jax.lax.slice_in_dim(buckets[f.bucket], f.offset,
                                        f.offset + f.size, axis=0)
            leaves[f.leaf] = flat.reshape(f.shape).astype(cast_to or f.dtype)
        return jax.tree.unflatten(plan.treedef, leaves)

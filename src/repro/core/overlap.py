"""Compute/communication overlap policies for gradient accumulation.

The paper devotes cores to *progressing communication concurrently with
compute*.  In XLA the latency-hiding scheduler overlaps async collectives
with independent compute automatically — our job is to *structure the step*
so independence exists:

* ``accumulate_then_reduce`` — sum microbatch gradients locally, reduce once
  (comm-minimal; reduction serialises after the last microbatch).
* ``stream`` — reduce each microbatch's buckets as they are produced; the
  reduction of microbatch ``i`` has no data dependency on the compute of
  microbatch ``i+1``, so the scheduler overlaps them (the paper's comm
  threads running while compute proceeds).  Same math (mean of means).

Microbatch loops are unrolled python loops so the HLO exposes the
independent collectives (and so dry-run cost analysis counts every step).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

POLICIES = ("accumulate_then_reduce", "stream")


@dataclass(frozen=True)
class AccumConfig:
    microbatches: int = 1
    policy: str = "accumulate_then_reduce"


def accumulate_and_reduce(grad_fn: Callable, reduce_fn: Callable, params,
                          batch, cfg: AccumConfig):
    """Run ``grad_fn(params, microbatch) -> (loss, grads)`` over ``cfg.microbatches``
    slices of ``batch`` (split on the leading axis), combining with the policy.

    ``reduce_fn(grads) -> grads`` performs the cross-device mean.
    Returns ``(mean_loss, reduced_grads)``.
    """
    if cfg.policy not in POLICIES:
        raise ValueError(f"unknown accumulation policy {cfg.policy!r}")
    m = cfg.microbatches
    if m <= 1:
        loss, grads = grad_fn(params, batch)
        return loss, reduce_fn(grads)

    micro = jax.tree.map(lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                         batch)
    inv = 1.0 / m
    losses = []
    if cfg.policy == "accumulate_then_reduce":
        acc = None
        for i in range(m):
            mb = jax.tree.map(lambda x: x[i], micro)
            loss, grads = grad_fn(params, mb)
            losses.append(loss)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)
            acc = grads if acc is None else jax.tree.map(jnp.add, acc, grads)
        reduced = reduce_fn(acc)
    else:  # stream: one reduction per microbatch, all independent
        acc = None
        for i in range(m):
            mb = jax.tree.map(lambda x: x[i], micro)
            loss, grads = grad_fn(params, mb)
            losses.append(loss)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)
            red = reduce_fn(grads)
            acc = red if acc is None else jax.tree.map(jnp.add, acc, red)
        reduced = acc
    return jnp.mean(jnp.stack(losses)), reduced

"""DEPRECATED compute/communication overlap shim.

The two string policies that used to live here are now *canned schedules*
in :mod:`repro.comm.schedule`: a :class:`~repro.comm.schedule.CommSchedule`
is an explicit ordered list of ``(phase, bucket_ids, channel)`` issue slots
derived from backward-pass readiness order, and
:meth:`repro.comm.Communicator.reduce_scheduled` executes it with per-rail
FIFO ordering.  The train step builds its schedule from
``TrainStepConfig.schedule`` (falling back to ``AccumConfig.policy``).

Kept here for backward compatibility:

* :class:`AccumConfig` — microbatch count + legacy policy name; consumed by
  ``TrainStepConfig`` and mapped onto a canned schedule via
  :func:`canned_schedule`.
* :func:`accumulate_and_reduce` — the old tree-granularity executor, now a
  deprecated wrapper over the same phase structure (no bucket-level issue
  order; use ``Communicator.reduce_scheduled`` for that).
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable, Sequence, TYPE_CHECKING

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # import cycle (comm.plan -> core package -> here)
    from repro.comm.schedule import CommSchedule

# legacy names (pre-schedule); "scheduled" is accepted everywhere a policy
# string is, but was never a POLICIES member
POLICIES = ("accumulate_then_reduce", "stream")


@dataclass(frozen=True)
class AccumConfig:
    """DEPRECATED: microbatching knob kept for config compatibility.

    ``policy`` accepts any :data:`~repro.comm.schedule.SCHEDULE_POLICIES`
    member; prefer setting ``TrainStepConfig.schedule`` in new code.
    """

    microbatches: int = 1
    policy: str = "accumulate_then_reduce"


def canned_schedule(cfg: AccumConfig, bucket_sizes: Sequence[int],
                    channels: int = 0) -> "CommSchedule":
    """Map a legacy :class:`AccumConfig` onto the schedule it always meant:
    ``accumulate_then_reduce`` -> one final-phase issue of every bucket,
    ``stream`` -> per-microbatch issues, ``scheduled`` passes through."""
    from repro.comm.schedule import SCHEDULE_POLICIES, build_schedule

    if cfg.policy not in SCHEDULE_POLICIES:
        raise ValueError(f"unknown accumulation policy {cfg.policy!r}; one "
                         f"of {SCHEDULE_POLICIES}")
    return build_schedule(cfg.policy, bucket_sizes,
                          microbatches=cfg.microbatches, channels=channels)


def accumulate_and_reduce(grad_fn: Callable, reduce_fn: Callable, params,
                          batch, cfg: AccumConfig):
    """DEPRECATED: run ``grad_fn(params, microbatch) -> (loss, grads)`` over
    ``cfg.microbatches`` slices of ``batch``, combining with the policy at
    *tree* granularity (``reduce_fn(grads) -> grads`` is the cross-device
    mean).  Returns ``(mean_loss, reduced_grads)``.

    Use :meth:`repro.comm.Communicator.reduce_scheduled` instead — it issues
    per-*bucket* collectives in readiness order on striped rails; this
    wrapper survives only for callers holding a bare ``reduce_fn``.
    """
    from repro.comm.schedule import SCHEDULE_POLICIES

    warnings.warn(
        "accumulate_and_reduce is deprecated; build a CommSchedule and call "
        "Communicator.reduce_scheduled", DeprecationWarning, stacklevel=2)
    if cfg.policy not in SCHEDULE_POLICIES:
        raise ValueError(f"unknown accumulation policy {cfg.policy!r}")
    m = cfg.microbatches
    if m <= 1:
        loss, grads = grad_fn(params, batch)
        return loss, reduce_fn(grads)

    micro = jax.tree.map(lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                         batch)
    inv = 1.0 / m
    streamed = cfg.policy != "accumulate_then_reduce"
    losses = []
    acc = None
    for i in range(m):
        mb = jax.tree.map(lambda x: x[i], micro)
        loss, grads = grad_fn(params, mb)
        losses.append(loss)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)
        if streamed:        # one reduction per microbatch, all independent
            grads = reduce_fn(grads)
        acc = grads if acc is None else jax.tree.map(jnp.add, acc, grads)
    reduced = acc if streamed else reduce_fn(acc)
    return jnp.mean(jnp.stack(losses)), reduced

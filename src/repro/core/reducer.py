"""GradientReducer — the paper's optimised gradient reduction as a first-class
framework feature.

Policies (each a faithful point in the paper's before/after space):

* ``baidu_original``  — the *published baseline* we accelerate, in JAX terms:
  one collective per tensor (no fusion), unidirectional single-channel ring,
  fp32 wire, flat (pod-oblivious) schedule.  This is the analogue of the
  un-modified baidu-allreduce: per-call buffers, one comm thread, 4 KB pages.
* ``fused_ring``      — + bucket fusion (T1/T2) + bidirectional chunked
  multi-channel rings (T3) + fused fp32 local reduce (T4).
* ``fused_ring_hierarchical`` — + pod-aware reduce-scatter/all-gather so
  cross-pod bytes shrink by the intra-pod axis size.  **Default.**
* ``fused_ring_compressed``   — + int8 block codec on the wire with source
  error feedback (beyond-paper).
* ``native_psum``     — XLA's built-in all-reduce, per tensor (vendor
  reference point).
* ``native_psum_fused`` — XLA's all-reduce over fused buckets (isolates the
  fusion win from the schedule win).

The reducer runs inside the jitted train step via ``jax.shard_map`` with all
mesh axes manual; tensor/model-sharded gradients are bucketed in each
device's *local* address space, reduced over the data axes only, and handed
back with their original sharding.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field, replace
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import ring as ring_lib
from repro.core.bucketing import GradientBucketer
from repro.core.compression import ErrorFeedback
from repro.core.ring import RingConfig
from repro.core.topology import reduce_axes_of

POLICIES = ("baidu_original", "fused_ring", "fused_ring_hierarchical",
            "fused_ring_compressed", "native_psum", "native_psum_fused")


@dataclass(frozen=True)
class ReduceConfig:
    policy: str = "fused_ring_hierarchical"
    data_axes: tuple[str, ...] = ("pod", "data")
    bucket_bytes: int = 4 * 2**20
    chunks: int = 2
    bidirectional: bool = True
    wire_dtype: str | None = None
    codec_block: int = 512
    local_op: str = "jnp"
    mean: bool = True

    def ring_config(self) -> RingConfig:
        if self.policy == "baidu_original":
            return RingConfig(chunks=1, bidirectional=False, wire_dtype=None,
                              local_op="jnp")
        codec = "int8" if self.policy == "fused_ring_compressed" else None
        return RingConfig(chunks=self.chunks, bidirectional=self.bidirectional,
                          wire_dtype=self.wire_dtype, local_op=self.local_op,
                          codec=codec, codec_block=self.codec_block)


class GradientReducer:
    """Reduces a (possibly model-sharded) gradient pytree over the data axes."""

    def __init__(self, mesh: Mesh, cfg: ReduceConfig = ReduceConfig()):
        if cfg.policy not in POLICIES:
            raise ValueError(f"unknown policy {cfg.policy!r}; one of {POLICIES}")
        self.mesh = mesh
        self.cfg = cfg
        self.axes = reduce_axes_of(mesh.axis_names, cfg.data_axes)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.axis_sizes = tuple(sizes[a] for a in self.axes)
        self.world = 1
        for s in self.axis_sizes:
            self.world *= s
        rcfg = cfg.ring_config()
        self._ring_cfg = rcfg
        pad = rcfg.flat_divisor(self.axis_sizes)
        self.bucketer = GradientBucketer(bucket_bytes=cfg.bucket_bytes,
                                         pad_multiple=pad)
        self._ef = (ErrorFeedback(rcfg.make_codec())
                    if cfg.policy == "fused_ring_compressed" else None)

    # -- schedule selection --------------------------------------------------

    def _reduce_flat(self, flat: jax.Array) -> jax.Array:
        cfg = self._ring_cfg
        if self.cfg.policy in ("fused_ring_hierarchical", "fused_ring_compressed"):
            # innermost mesh axis last in self.axes is the fastest-varying;
            # reduce-scatter over it first (intra-pod), recurse outward.
            ordered = tuple(reversed(self.axes))
            return ring_lib.hierarchical_all_reduce(flat, ordered, cfg)
        return ring_lib.flat_all_reduce(flat, self.axes, cfg)

    # -- public API ------------------------------------------------------------

    def __call__(self, grads, specs, ef_state=None):
        return self.reduce(grads, specs, ef_state)

    def reduce(self, grads, specs, ef_state=None):
        """Reduce ``grads`` (mean over the data axes) inside a jitted step.

        ``specs``: pytree of ``PartitionSpec`` congruent with ``grads``
        (the model-sharding of each gradient).  Returns ``(reduced, ef_state)``
        where ``ef_state`` is None unless the policy carries error feedback.
        """
        if not self.axes:
            return grads, ef_state

        ef_spec = P(tuple(self.mesh.axis_names))
        has_ef = self._ef is not None and ef_state is not None
        in_specs = (specs, ef_spec) if has_ef else (specs,)
        out_specs = (specs, ef_spec) if has_ef else (specs,)

        def inner(*args):
            g = args[0]
            if self.cfg.policy == "native_psum":
                red = jax.tree.map(
                    lambda x: lax.psum(x, self.axes), g)
                red = self._maybe_mean_tree(red)
                return (red, args[1]) if has_ef else (red,)

            buckets, plan = self.bucketer.bucketize(g)
            new_res = None
            if has_ef:
                residuals = list(args[1])
                buckets, new_res = self._ef.compensate(buckets, residuals)
            if self.cfg.policy == "native_psum_fused":
                reduced = [lax.psum(b, self.axes) for b in buckets]
            elif self.cfg.policy == "baidu_original":
                # per-tensor: bucketer configured per-leaf below
                reduced = [self._reduce_flat(b) for b in buckets]
            else:
                reduced = [self._reduce_flat(b) for b in buckets]
            if self.cfg.mean:
                inv = jnp.asarray(1.0 / self.world, jnp.float32)
                reduced = [b * inv for b in reduced]
            red_tree = self.bucketer.debucketize(reduced, plan)
            return (red_tree, new_res) if has_ef else (red_tree,)

        args = (grads, ef_state) if has_ef else (grads,)
        out = jax.shard_map(inner, mesh=self.mesh, in_specs=in_specs,
                            out_specs=out_specs, check_vma=False)(*args)
        return (out[0], out[1]) if has_ef else (out[0], ef_state)

    def _maybe_mean_tree(self, tree):
        if not self.cfg.mean:
            return tree
        inv = 1.0 / self.world
        return jax.tree.map(lambda x: (x.astype(jnp.float32) * inv).astype(x.dtype),
                            tree)

    # -- manual-mode entry points (called INSIDE a fully-manual shard_map) -----

    def _ordered_axes(self) -> tuple[str, ...]:
        """Innermost (fastest/intra-pod) axis first for hierarchical order."""
        return tuple(reversed(self.axes))

    def reduce_manual(self, grads, ef_state=None):
        """All-reduce-mean a local gradient pytree (full-manual context)."""
        if not self.axes:
            return grads, ef_state
        if self.cfg.policy == "native_psum":
            red = jax.tree.map(lambda x: lax.psum(x, self.axes), grads)
            return self._maybe_mean_tree(red), ef_state
        buckets, plan = self.bucketer.bucketize(grads)
        new_res = ef_state
        if self._ef is not None and ef_state is not None:
            buckets, new_res = self._ef.compensate(buckets, list(ef_state))
        if self.cfg.policy == "native_psum_fused":
            reduced = [lax.psum(b, self.axes) for b in buckets]
        else:
            reduced = [self._reduce_flat(b) for b in buckets]
        if self.cfg.mean:
            inv = jnp.asarray(1.0 / self.world, jnp.float32)
            reduced = [b * inv for b in reduced]
        return self.bucketer.debucketize(reduced, plan), new_res

    def reduce_scatter_manual(self, grads):
        """Reduce-scatter-mean into flat bucket shards (ZeRO path).

        Hierarchical: RS over the intra-pod axis first, then RS the shard
        over the pod axis.  Returns (shards, plan); invert with
        :meth:`all_gather_manual`."""
        buckets, plan = self.bucketer.bucketize(grads)
        cfg = self._ring_cfg
        shards = []
        inv = jnp.asarray(1.0 / self.world if self.cfg.mean else 1.0,
                          jnp.float32)
        for b in buckets:
            for axis in self._ordered_axes():
                b = ring_lib.ring_reduce_scatter(b, axis, cfg)
            shards.append(b * inv)
        return shards, plan

    def all_gather_manual(self, shards, plan=None):
        """Inverse of :meth:`reduce_scatter_manual`; returns full buckets
        (or the debucketized tree when ``plan`` is given)."""
        cfg = self._ring_cfg
        full = []
        for s in shards:
            for axis in reversed(self._ordered_axes()):
                s = ring_lib.ring_all_gather(s, axis, cfg)
            full.append(s)
        return full if plan is None else self.bucketer.debucketize(full, plan)

    # -- error-feedback state ---------------------------------------------------

    def init_ef_state(self, grads_like, specs):
        """Zero residual buckets, as *global* arrays sharded one-local-bucket
        per device (leading dim = all mesh axes).  ``grads_like`` may be
        ShapeDtypeStructs."""
        if self._ef is None:
            return None
        ef_spec = P(tuple(self.mesh.axis_names))

        def inner(g):
            buckets, _ = self.bucketer.bucketize(g)
            return [jnp.zeros_like(b) for b in buckets]

        fn = jax.shard_map(inner, mesh=self.mesh, in_specs=(specs,),
                           out_specs=ef_spec, check_vma=False)
        return jax.jit(fn)(grads_like) if not _is_abstract(grads_like) \
            else jax.eval_shape(fn, grads_like)

    # -- analysis ----------------------------------------------------------------

    def predicted_collective_bytes(self, grads_like) -> dict[str, float]:
        """Napkin-math bytes per device for §Perf hypothesis logs."""
        leaves = jax.tree.leaves(grads_like)
        n = sum(int(jnp.size(l)) if hasattr(l, "size") else 0 for l in leaves)
        itemsize = 4
        codec = self._ring_cfg.make_codec()
        wire_per_elem = codec.wire_bytes(max(n, 1)) / max(n, 1)
        out = {}
        if self.cfg.policy in ("fused_ring_hierarchical", "fused_ring_compressed"):
            inner_p = self.axis_sizes[-1]
            outer = self.world // inner_p
            # RS+AG on inner axis: 2*(p-1)/p * n; cross level on n/p shard
            inner_bytes = 2 * (inner_p - 1) / inner_p * n * wire_per_elem
            outer_bytes = (2 * (outer - 1) / outer * (n / inner_p) * wire_per_elem
                           if outer > 1 else 0.0)
            out["bytes_per_device"] = inner_bytes + outer_bytes
        else:
            total = 0.0
            for p in self.axis_sizes:
                total += 2 * (p - 1) / p * n * itemsize
            out["bytes_per_device"] = total
        out["grad_bytes"] = n * itemsize
        return out


def _is_abstract(tree) -> bool:
    leaves = jax.tree.leaves(tree)
    return bool(leaves) and isinstance(leaves[0], jax.ShapeDtypeStruct)


def per_tensor_reducer(mesh: Mesh, cfg: ReduceConfig) -> "GradientReducer":
    """The faithful 'baidu_original' baseline: bucket_bytes=1 forces one
    bucket per tensor (no fusion), matching the published code's per-call
    buffer behaviour."""
    cfg = replace(cfg, policy="baidu_original", bucket_bytes=1)
    return GradientReducer(mesh, cfg)

"""GradientReducer — DEPRECATED shim over :class:`repro.comm.Communicator`.

The string-policy reducer has been replaced by the unified ``repro.comm``
subsystem: named transports in a registry (:mod:`repro.comm.registry`),
channel striping and bucket layout fused into a :class:`repro.comm.CommPlan`,
and one :class:`~repro.comm.Communicator` object shared by gradient
reduction and halo exchange.  Policy names map onto transports:

=========================  ==============================================
``baidu_original``         ``ring`` (chunks=1, unidirectional, fp32 wire)
``fused_ring``             ``ring``
``fused_ring_hierarchical``  ``ring_hier``  (default)
``fused_ring_compressed``  ``ring_hier`` + ``wire_codec='int8'``
``native_psum``            ``psum`` (fuse=False, per-tensor)
``native_psum_fused``      ``psum``
=========================  ==============================================

Old call sites keep working unchanged; new code should construct a
``Communicator`` directly::

    from repro.comm import CommConfig, Communicator
    comm = Communicator(mesh, CommConfig(transport="ring_hier", channels=2))
    reduced, _ = comm.reduce(grads, specs)
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace

import jax
from jax.sharding import Mesh

from repro.core.ring import RingConfig

# NOTE: repro.comm is imported lazily inside the shim: repro.comm.api itself
# imports repro.core submodules, and importing it here at module level would
# close an import cycle through repro.core.__init__.

POLICIES = ("baidu_original", "fused_ring", "fused_ring_hierarchical",
            "fused_ring_compressed", "native_psum", "native_psum_fused")

# former ReduceConfig.policy -> (transport, CommConfig field overrides).
# Lives here — with the rest of the string-policy compatibility shim — so
# no production code path depends on the legacy table; repro.comm
# re-exports it for old importers.
POLICY_TO_TRANSPORT: dict[str, tuple[str, dict]] = {
    "baidu_original": ("ring", {"chunks": 1, "bidirectional": False,
                                "wire_dtype": None, "local_op": "jnp"}),
    "fused_ring": ("ring", {}),
    "fused_ring_hierarchical": ("ring_hier", {}),
    "fused_ring_compressed": ("ring_hier", {"wire_codec": "int8"}),
    "native_psum": ("psum", {"fuse": False}),
    "native_psum_fused": ("psum", {}),
}


def comm_config_from_policy(policy: str, **fields):
    """Map a legacy ``ReduceConfig.policy`` name onto a
    :class:`repro.comm.CommConfig`.

    ``fields`` are CommConfig overrides taken from the legacy config; the
    policy's own forced overrides (e.g. ``baidu_original`` => unidirectional
    single-chunk) win over them.
    """
    from repro.comm.api import CommConfig

    try:
        transport, forced = POLICY_TO_TRANSPORT[policy]
    except KeyError:
        raise ValueError(
            f"unknown policy {policy!r}; one of "
            f"{tuple(POLICY_TO_TRANSPORT)}") from None
    base = CommConfig(transport=transport)
    merged = {**fields, **forced}
    known = {k: v for k, v in merged.items() if hasattr(base, k)}
    return replace(base, **known)


@dataclass(frozen=True)
class ReduceConfig:
    """Legacy string-policy config; converts to :class:`CommConfig`."""

    policy: str = "fused_ring_hierarchical"
    data_axes: tuple[str, ...] = ("pod", "data")
    bucket_bytes: int = 4 * 2**20
    chunks: int = 2
    bidirectional: bool = True
    wire_dtype: str | None = None
    codec_block: int = 512
    local_op: str = "jnp"
    mean: bool = True

    def comm_config(self, channels: int = 0):
        return comm_config_from_policy(
            self.policy, data_axes=self.data_axes,
            bucket_bytes=self.bucket_bytes, chunks=self.chunks,
            bidirectional=self.bidirectional, wire_dtype=self.wire_dtype,
            codec_block=self.codec_block, local_op=self.local_op,
            mean=self.mean, channels=channels)

    def ring_config(self) -> RingConfig:
        ccfg = self.comm_config()
        codec = "int8" if self.policy == "fused_ring_compressed" else None
        return ccfg.ring_config(codec=codec)


class GradientReducer:
    """Thin deprecated facade; every operation delegates to the
    :class:`Communicator` it constructs."""

    def __init__(self, mesh: Mesh, cfg: ReduceConfig = ReduceConfig()):
        from repro.comm.api import Communicator

        if cfg.policy not in POLICIES:
            raise ValueError(f"unknown policy {cfg.policy!r}; one of {POLICIES}")
        warnings.warn(
            "GradientReducer is deprecated; use repro.comm.Communicator "
            f"(policy {cfg.policy!r} -> transport "
            f"{POLICY_TO_TRANSPORT[cfg.policy][0]!r})",
            DeprecationWarning, stacklevel=2)
        self.mesh = mesh
        self.cfg = cfg
        self.comm = Communicator(mesh, cfg.comm_config())
        # legacy attribute surface
        self.axes = self.comm.axes
        self.axis_sizes = self.comm.axis_sizes
        self.world = self.comm.world
        self.bucketer = self.comm.bucketer
        self._ring_cfg = self.comm._ring_cfg
        self._ef = self.comm._ef

    # -- public API ----------------------------------------------------------

    def __call__(self, grads, specs, ef_state=None):
        return self.reduce(grads, specs, ef_state)

    def reduce(self, grads, specs, ef_state=None):
        """SPMD-level reduce-mean; see :meth:`Communicator.reduce`."""
        return self.comm.reduce(grads, specs, ef_state)

    # -- manual-mode entry points (called INSIDE a fully-manual shard_map) ---

    def _ordered_axes(self) -> tuple[str, ...]:
        return self.comm.ordered_axes

    def reduce_manual(self, grads, ef_state=None):
        return self.comm.all_reduce_tree(grads, ef_state)

    def reduce_scatter_manual(self, grads):
        return self.comm.reduce_scatter_tree(grads)

    def all_gather_manual(self, shards, plan=None):
        return self.comm.all_gather_buckets(shards, plan)

    # -- error-feedback state ------------------------------------------------

    def init_ef_state(self, grads_like, specs):
        return self.comm.init_ef_state(grads_like, specs)

    # -- analysis ------------------------------------------------------------

    def predicted_collective_bytes(self, grads_like) -> dict[str, float]:
        return self.comm.predicted_collective_bytes(grads_like)


def per_tensor_reducer(mesh: Mesh, cfg: ReduceConfig) -> "GradientReducer":
    """The faithful 'baidu_original' baseline: bucket_bytes=1 forces one
    bucket per tensor (no fusion), matching the published code's per-call
    buffer behaviour."""
    cfg = replace(cfg, policy="baidu_original", bucket_bytes=1)
    return GradientReducer(mesh, cfg)

"""Explicit ring collectives built from ``lax.ppermute``.

This is the TPU-native reconstruction of the paper's optimised Baidu
all-reduce: the reduction is expressed as an explicit reduce-scatter +
all-gather ring whose *schedule* we control, instead of a single opaque
``lax.psum``.  The paper's techniques map directly:

* **bidirectional rings** — each segment's payload is split in half and the
  halves travel clockwise / counter-clockwise simultaneously, driving both
  directions of every ICI link (the paper's dual-rail usage);
* **chunked multi-channel transfers** — the payload is further split into
  ``chunks`` independent ppermute chains with no data dependencies between
  them, so the async collective-permute DMAs pipeline (the paper's eight
  threaded PSM2 endpoints);
* **fused local reduce** — the per-hop ``acc += recv`` is the paper's
  OpenMP-threaded reduce loop; here a VPU-aligned fused op (optionally the
  ``kernels/reduce_add`` Pallas kernel) with fp32 accumulation;
* **wire codecs** (beyond-paper) — hops can carry bf16 or block-int8
  payloads (``repro.comm.wire_codec``), shrinking collective bytes.

All functions operate on *flat, pre-padded* 1-D buffers inside a
``shard_map`` manual context (``core.bucketing`` produces those buffers).
Loops over the ``p - 1`` ring steps are deliberately unrolled so the compiled
HLO exposes every collective-permute to the scheduler and to our roofline
collective-byte accounting.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core.topology import ring_perm

LocalAdd = Callable[[jax.Array, jax.Array], jax.Array]


@dataclass(frozen=True)
class RingConfig:
    """Static schedule knobs (compile-time; the paper's 'guaranteed' ethos)."""

    chunks: int = 1
    bidirectional: bool = True
    wire_dtype: str | None = None      # None = carry accum dtype on the wire
    accum_dtype: str = "float32"
    local_op: str = "jnp"              # "jnp" | "pallas" (kernels/reduce_add)
    codec: str | None = None           # None | "int8" (per-hop block codec)
    codec_block: int = 512

    def make_codec(self):
        # lazy: repro.comm.wire_codec is the codec's first-class home, and
        # importing repro.comm at module level would close a cycle through
        # repro.comm.api -> repro.core.ring
        from repro.comm.wire_codec import make_codec

        return make_codec(self.codec, wire_dtype=self.wire_dtype,
                          block=self.codec_block)

    @property
    def channel_divisor(self) -> int:
        """Per-segment width divisor imposed by channels + codec blocks."""
        d = self.chunks * (2 if self.bidirectional else 1)
        if self.codec is not None:
            d *= self.codec_block
        return d

    def flat_divisor(self, axis_sizes: Sequence[int]) -> int:
        """Flat-buffer length divisor for a (possibly hierarchical) schedule.

        RS over the innermost axis hands ``L / p`` to the next level, so the
        requirement composes multiplicatively across axes.
        """
        d = 1
        for p in axis_sizes:
            d *= p * self.channel_divisor
        return max(d, 1)


def _resolve_local_add(cfg: RingConfig) -> LocalAdd:
    accum = jnp.dtype(cfg.accum_dtype)
    if cfg.local_op == "pallas":
        from repro.kernels.reduce_add import ops as ra_ops

        return functools.partial(ra_ops.add_accum, accum_dtype=accum)

    def _add(a: jax.Array, b: jax.Array) -> jax.Array:
        return a.astype(accum) + b.astype(accum)

    return _add


def _tree_ppermute(payload, axis: str, perm):
    return jax.tree.map(lambda a: lax.ppermute(a, axis, perm), payload)


# ---------------------------------------------------------------------------
# single-direction primitives on contiguous (p * s,) buffers
# ---------------------------------------------------------------------------


def _rs_1d(x: jax.Array, axis: str, direction: int, cfg: RingConfig,
           local_add: LocalAdd, codec) -> jax.Array:
    """Ring reduce-scatter; device ``r`` ends owning the full sum of segment
    ``r`` (i.e. ``x[r*s:(r+1)*s]`` summed over the axis)."""
    accum = jnp.dtype(cfg.accum_dtype)
    p = compat.axis_size(axis)
    if p == 1:
        return x.astype(accum)
    r = lax.axis_index(axis)
    seg = x.shape[0] // p
    xs = x.reshape(p, seg)
    perm = ring_perm(p, direction)

    # Ownership offset chosen so the final fully-reduced segment is ``r``.
    off = -direction
    idx0 = (r + off) % p
    acc = lax.dynamic_index_in_dim(xs, idx0, axis=0, keepdims=False)
    acc = acc.astype(accum)
    for s in range(p - 1):
        recv = _tree_ppermute(codec.encode(acc), axis, perm)
        nxt = (r + off - (s + 1) * direction) % p
        mine = lax.dynamic_index_in_dim(xs, nxt, axis=0, keepdims=False)
        acc = local_add(codec.decode(recv), mine)
    return acc


def _ag_1d(shard: jax.Array, axis: str, direction: int, codec) -> jax.Array:
    """Ring all-gather of per-device segment ``r`` into the full (p*s,) buffer.

    The payload is encoded *once* at the source and forwarded verbatim, so a
    lossy codec costs a single quantisation (no per-hop compounding).
    """
    p = compat.axis_size(axis)
    if p == 1:
        return shard
    r = lax.axis_index(axis)
    perm = ring_perm(p, direction)
    payload = codec.encode(shard)
    outs = jax.tree.map(
        lambda a: lax.dynamic_update_index_in_dim(
            jnp.zeros((p,) + a.shape, a.dtype), a, r, axis=0),
        payload)
    cur = payload
    for s in range(p - 1):
        cur = _tree_ppermute(cur, axis, perm)
        idx = (r - (s + 1) * direction) % p
        outs = jax.tree.map(
            lambda o, c: lax.dynamic_update_index_in_dim(o, c, idx, axis=0),
            outs, cur)
    decoded = jax.vmap(codec.decode)(outs)
    return decoded.reshape(-1).astype(shard.dtype)


# ---------------------------------------------------------------------------
# multi-channel (bidirectional x chunked) schedules
# ---------------------------------------------------------------------------


def _channel_slices(seg: int, cfg: RingConfig) -> list[tuple[int, int, int]]:
    """(start, width, direction) channel layout of one owned segment."""
    w = seg // cfg.chunks
    out = []
    for c in range(cfg.chunks):
        base = c * w
        if cfg.bidirectional:
            h = w // 2
            out.append((base, h, +1))
            out.append((base + h, w - h, -1))
        else:
            out.append((base, w, +1))
    return out


def _check_divisible(seg: int, cfg: RingConfig) -> None:
    if seg % (cfg.channel_divisor or 1) != 0:
        raise ValueError(
            f"segment {seg} not divisible by channel divisor "
            f"{cfg.channel_divisor} (chunks={cfg.chunks}, "
            f"bidirectional={cfg.bidirectional}, codec={cfg.codec})")


def ring_reduce_scatter(x: jax.Array, axis: str, cfg: RingConfig = RingConfig()) -> jax.Array:
    """Multi-channel ring reduce-scatter of a flat buffer.

    ``x``: (L,), ``L % (p * channel_divisor) == 0``.  Returns device ``r``'s
    fully-reduced segment ``x[r*L/p:(r+1)*L/p]`` in ``cfg.accum_dtype``.
    """
    p = compat.axis_size(axis)
    L = x.shape[0]
    if L % max(p, 1) != 0:
        raise ValueError(f"flat length {L} not divisible by ring size {p}")
    seg = L // p
    _check_divisible(seg, cfg)
    local_add = _resolve_local_add(cfg)
    codec = cfg.make_codec()
    xs = x.reshape(p, seg)
    shards = []
    for (start, width, direction) in _channel_slices(seg, cfg):
        part = lax.slice_in_dim(xs, start, start + width, axis=1)
        shards.append(_rs_1d(part.reshape(-1), axis, direction, cfg,
                             local_add, codec))
    return jnp.concatenate(shards) if len(shards) > 1 else shards[0]


def ring_all_gather(shard: jax.Array, axis: str, cfg: RingConfig = RingConfig()) -> jax.Array:
    """Inverse of :func:`ring_reduce_scatter` (same channel layout)."""
    seg = shard.shape[0]
    _check_divisible(seg, cfg)
    p = compat.axis_size(axis)
    codec = cfg.make_codec()
    gathered = []  # (p, width) blocks in channel order
    for (start, width, direction) in _channel_slices(seg, cfg):
        part = lax.slice_in_dim(shard, start, start + width, axis=0)
        gathered.append(_ag_1d(part, axis, direction, codec).reshape(p, width))
    blocks = jnp.concatenate(gathered, axis=1) if len(gathered) > 1 else gathered[0]
    return blocks.reshape(-1)


def ring_all_reduce(x: jax.Array, axis: str, cfg: RingConfig = RingConfig()) -> jax.Array:
    """Bandwidth-optimal all-reduce: reduce-scatter followed by all-gather."""
    shard = ring_reduce_scatter(x, axis, cfg)
    return ring_all_gather(shard, axis, cfg)


# ---------------------------------------------------------------------------
# multi-axis (pod-aware) schedules
# ---------------------------------------------------------------------------


def hierarchical_all_reduce(x: jax.Array, axes: Sequence[str],
                            cfg: RingConfig = RingConfig()) -> jax.Array:
    """Pod-aware all-reduce: RS over the innermost (fast, intra-pod) axis,
    recurse over the outer axes on the 1/p shard, then AG back.

    Cross-pod traffic shrinks by the intra-pod axis size versus a flat
    schedule — the paper's 'drive the fat local links concurrently' insight
    applied across the pod boundary.
    """
    if len(axes) == 0:
        return x
    if len(axes) == 1:
        return ring_all_reduce(x, axes[0], cfg)
    inner, outer = axes[0], axes[1:]
    shard = ring_reduce_scatter(x, inner, cfg)
    shard = hierarchical_all_reduce(shard, outer, cfg)
    return ring_all_gather(shard, inner, cfg)


def flat_all_reduce(x: jax.Array, axes: Sequence[str],
                    cfg: RingConfig = RingConfig()) -> jax.Array:
    """Naive multi-axis schedule: full-size ring all-reduce per axis in turn.

    This is the multi-pod *baseline*: every byte crosses the inter-pod links
    at full size.  Kept for §Perf before/after comparisons.
    """
    for axis in axes:
        x = ring_all_reduce(x, axis, cfg)
    return x


# ---------------------------------------------------------------------------
# all-to-all (expert-parallel dispatch/combine)
# ---------------------------------------------------------------------------


def ring_all_to_all(x: jax.Array, axis: str, *, split_axis: int,
                    concat_axis: int) -> jax.Array:
    """Explicit all-to-all built from ``p - 1`` pairwise ppermute hops.

    Semantics match ``lax.all_to_all(..., tiled=True)``: ``x`` is split into
    ``p`` equal blocks along ``split_axis``; block ``j`` travels to device
    ``j``; the received blocks (one per source, in source order) are
    concatenated along ``concat_axis``.

    Hop ``s`` ships each device's block for destination ``(r + s) % p`` via
    the uniform shift permutation ``r -> (r + s) % p`` — every hop drives all
    links concurrently (the paper's concurrency-through-the-stack pattern)
    and each block crosses the wire exactly once, so per-device wire traffic
    is ``(p - 1)/p`` of the payload in ``p - 1`` messages.

    Every op here is linear (slice/stack/roll/ppermute), so the autodiff
    transpose is the exact inverse all-to-all — no custom VJP needed.
    """
    p = compat.axis_size(axis)
    n = x.shape[split_axis]
    if n % max(p, 1) != 0:
        raise ValueError(
            f"all_to_all split dim {n} not divisible by axis size {p}")
    if p == 1:
        return x
    blk = n // p
    blocks = [lax.slice_in_dim(x, j * blk, (j + 1) * blk, axis=split_axis)
              for j in range(p)]
    xs = jnp.stack(blocks, axis=0)                       # (p_dst, ...)
    r = lax.axis_index(axis)
    # z[s] = block destined for rank (r + s) % p (rank-dependent shift of a
    # traced amount — roll keeps this inside one fused gather).
    z = jnp.roll(xs, -r, axis=0)
    recv = [z[0]]                                        # own block, hop 0
    for s in range(1, p):
        perm = [(src, (src + s) % p) for src in range(p)]
        recv.append(lax.ppermute(z[s], axis, perm))
    stack = jnp.stack(recv, axis=0)                      # stack[s] <- rank (r - s) % p
    # Reorder hop order -> source order: w[j] = stack[(r - j) % p].
    w = jnp.roll(stack[::-1], r + 1, axis=0)
    return jnp.concatenate([w[j] for j in range(p)], axis=concat_axis)

"""Cartesian halo exchange over mesh axes (the paper's QCD workload).

Mirrors ``Grid``'s ``Benchmark_comms``: every rank sends its faces to the
+/- neighbours along each Cartesian direction.  Four schedules reproduce
the paper's experimental columns:

* ``sequential``  — one direction at a time, each transfer data-dependent on
  the previous (the 'Seq' columns): a token is threaded through the chain so
  XLA cannot overlap them.
* ``concurrent``  — all directions issued as independent ``ppermute`` ops
  (the 'Concurrent' columns): the scheduler may overlap every face transfer.
* ``chunked``     — each face additionally split into ``chunks`` independent
  channels (the 'Threaded' multi-EP columns).  Faces whose split dim is not
  divisible split unevenly (:func:`chunk_sizes`) rather than degrading to a
  single chunk.
* ``overlap``     — whole faces striped across ``channels`` guaranteed rails
  (per-rail FIFO via order tokens, like scheduled bucket reduction); meant
  to be consumed by an interior/boundary-split operator
  (:mod:`repro.stencil.op`) so interior compute hides the transfers.  The
  matching issue slots come from
  :func:`repro.comm.schedule.build_halo_schedule`.

Runs inside ``shard_map`` with the participating axes manual.  Used by the
QCD-style stencil solver and by context/sequence-parallel layers; the
preferred entry point is :meth:`repro.comm.Communicator.halo_exchange`,
which ties the ``chunks``/``channels`` knobs to the communicator's virtual
channels so SGD reduction and QCD halo share one multi-rail configuration.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core.topology import order_token, ring_perm

SCHEDULES = ("sequential", "concurrent", "chunked", "overlap")


@dataclass(frozen=True)
class HaloSpec:
    """One exchanged direction: array dim ``dim`` over mesh axis ``axis``."""

    axis: str           # mesh axis name
    dim: int            # array dimension sharded over that axis
    halo: int = 1       # face width


def _face(x: jax.Array, dim: int, lo: bool, width: int) -> jax.Array:
    n = x.shape[dim]
    if lo:
        return lax.slice_in_dim(x, 0, width, axis=dim)
    return lax.slice_in_dim(x, n - width, n, axis=dim)


def face_split_dim(shape: Sequence[int], dim: int) -> int:
    """The dim a face is chunked along: largest non-halo dim, so pieces stay
    contiguous (``dim`` itself only when the face is 1-D)."""
    return max((d for d in range(len(shape)) if d != dim),
               key=lambda d: shape[d], default=dim)


def chunk_sizes(n: int, chunks: int) -> list[int]:
    """Piece lengths splitting ``n`` into ``min(chunks, n)`` near-equal
    parts: the first ``n % k`` pieces are one longer.  Shared by the
    executor (:func:`_split_chunks`) and the prediction layer
    (:func:`repro.comm.schedule.build_halo_schedule`) so predicted and
    lowered payload bytes agree for indivisible shapes."""
    k = max(1, min(int(chunks), int(n)))
    base, extra = divmod(int(n), k)
    return [base + 1] * extra + [base] * (k - extra)


def _split_chunks(face: jax.Array, chunks: int, dim: int) -> list[jax.Array]:
    if chunks <= 1:
        return [face]
    split_dim = face_split_dim(face.shape, dim)
    out, start = [], 0
    for c in chunk_sizes(face.shape[split_dim], chunks):
        out.append(lax.slice_in_dim(face, start, start + c, axis=split_dim))
        start += c
    return out


def _seq_token(dep: jax.Array, arrs: Sequence[jax.Array]) -> list[jax.Array]:
    """Thread a scalar data dependency through ``arrs`` to force ordering."""
    out = []
    for a in arrs:
        a = order_token(dep, a)
        dep = a.reshape(-1)[0]
        out.append(a)
    return out


def halo_exchange(x: jax.Array, specs: Sequence[HaloSpec], *,
                  schedule: str = "concurrent", chunks: int = 4,
                  channels: int = 0) -> dict:
    """Exchange faces along every spec'd direction.

    Returns ``{(axis, '+'): received_hi_face, (axis, '-'): received_lo_face}``
    — the halos a stencil kernel pads with.  '+' is the face received *from*
    the +1 neighbour (i.e. their low face), usable as this rank's high halo.

    ``channels`` only matters to the ``overlap`` schedule: ``>= 1`` stripes
    the faces across that many guaranteed rails, each issuing FIFO through
    an order token (exactly :meth:`Communicator.reduce_scheduled`'s rail
    rule); ``0`` leaves every face an unconstrained independent transfer.
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule must be one of {SCHEDULES}")

    sends = []  # (key, payloads, axis, direction)
    for s in specs:
        p = compat.axis_size(s.axis)
        n_chunks = chunks if (schedule == "chunked" and p > 1) else 1
        hi = _face(x, s.dim, lo=False, width=s.halo)   # travels to +1; recv as lo-halo
        lo = _face(x, s.dim, lo=True, width=s.halo)    # travels to -1; recv as hi-halo
        sends.append(((s.axis, "-"), _split_chunks(hi, n_chunks, s.dim), s.axis, +1))
        sends.append(((s.axis, "+"), _split_chunks(lo, n_chunks, s.dim), s.axis, -1))

    rail_of = None
    if schedule == "overlap" and channels >= 1:
        # core<->comm layering: the striping rule lives with the channel
        # machinery; import lazily to avoid the package-init cycle
        from repro.comm.plan import assign_channels

        sizes = [sum(math.prod(c.shape) for c in payloads)
                 for _, payloads, _, _ in sends]
        rail_of = {}
        for a in assign_channels(sizes, channels):
            for u in a.buckets:
                rail_of[u] = a.channel

    out: dict = {}
    dep = None
    rail_dep: dict[int, jax.Array] = {}
    for idx, (key, payloads, axis, direction) in enumerate(sends):
        p = compat.axis_size(axis)
        perm = ring_perm(p, direction)
        if schedule == "sequential" and dep is not None:
            payloads = _seq_token(dep, payloads)
        if rail_of is not None:
            payloads = [order_token(rail_dep.get(rail_of[idx]), c)
                        for c in payloads]
        received = [lax.ppermute(c, axis, perm) for c in payloads]
        if schedule == "sequential":
            dep = received[-1].reshape(-1)[0]
        if rail_of is not None:
            rail_dep[rail_of[idx]] = received[-1].reshape(-1)[0]
        face = received[0] if len(received) == 1 else _reassemble(received, key, specs, x.shape)
        out[key] = face
    return out


def _reassemble(parts: list[jax.Array], key, specs, x_shape) -> jax.Array:
    spec = next(s for s in specs if s.axis == key[0])
    face_shape = list(x_shape)
    face_shape[spec.dim] = spec.halo
    return jnp.concatenate(parts, axis=face_split_dim(face_shape, spec.dim))


def pad_with_halos(x: jax.Array, halos: dict, spec: HaloSpec) -> jax.Array:
    """Concatenate received halos onto ``x`` along ``spec.dim``."""
    lo = halos[(spec.axis, "-")]
    hi = halos[(spec.axis, "+")]
    return jnp.concatenate([lo, x, hi], axis=spec.dim)


def halo_bytes(x_shape: Sequence[int], specs: Sequence[HaloSpec], itemsize: int) -> int:
    """Bidirectional bytes injected per device per exchange (analysis)."""
    total = 0
    for s in specs:
        face = 1
        for d, n in enumerate(x_shape):
            face *= s.halo if d == s.dim else n
        total += 2 * face * itemsize
    return total

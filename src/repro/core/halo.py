"""Cartesian halo exchange over mesh axes (the paper's QCD workload).

Mirrors ``Grid``'s ``Benchmark_comms``: every rank sends its faces to the
+/- neighbours along each Cartesian direction.  Three schedules reproduce
the paper's experimental columns:

* ``sequential``  — one direction at a time, each transfer data-dependent on
  the previous (the 'Seq' columns): a token is threaded through the chain so
  XLA cannot overlap them.
* ``concurrent``  — all directions issued as independent ``ppermute`` ops
  (the 'Concurrent' columns): the scheduler may overlap every face transfer.
* ``chunked``     — each face additionally split into ``chunks`` independent
  channels (the 'Threaded' multi-EP columns).

Runs inside ``shard_map`` with the participating axes manual.  Used by the
QCD-style stencil example and by context/sequence-parallel layers; the
preferred entry point is :meth:`repro.comm.Communicator.halo_exchange`,
which ties the ``chunks`` knob to the communicator's virtual channels so
SGD reduction and QCD halo share one multi-rail configuration.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core.topology import order_token, ring_perm

SCHEDULES = ("sequential", "concurrent", "chunked")


@dataclass(frozen=True)
class HaloSpec:
    """One exchanged direction: array dim ``dim`` over mesh axis ``axis``."""

    axis: str           # mesh axis name
    dim: int            # array dimension sharded over that axis
    halo: int = 1       # face width


def _face(x: jax.Array, dim: int, lo: bool, width: int) -> jax.Array:
    n = x.shape[dim]
    if lo:
        return lax.slice_in_dim(x, 0, width, axis=dim)
    return lax.slice_in_dim(x, n - width, n, axis=dim)


def _split_chunks(face: jax.Array, chunks: int, dim: int) -> list[jax.Array]:
    if chunks <= 1:
        return [face]
    # chunk along the largest non-halo dim to keep faces contiguous
    split_dim = max((d for d in range(face.ndim) if d != dim),
                    key=lambda d: face.shape[d], default=dim)
    if face.shape[split_dim] % chunks != 0:
        return [face]
    return list(jnp.split(face, chunks, axis=split_dim))


def _seq_token(dep: jax.Array, arrs: Sequence[jax.Array]) -> list[jax.Array]:
    """Thread a scalar data dependency through ``arrs`` to force ordering."""
    out = []
    for a in arrs:
        a = order_token(dep, a)
        dep = a.reshape(-1)[0]
        out.append(a)
    return out


def halo_exchange(x: jax.Array, specs: Sequence[HaloSpec], *,
                  schedule: str = "concurrent", chunks: int = 4) -> dict:
    """Exchange faces along every spec'd direction.

    Returns ``{(axis, '+'): received_hi_face, (axis, '-'): received_lo_face}``
    — the halos a stencil kernel pads with.  '+' is the face received *from*
    the +1 neighbour (i.e. their low face), usable as this rank's high halo.
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule must be one of {SCHEDULES}")

    sends = []  # (key, payloads, axis, direction)
    for s in specs:
        p = compat.axis_size(s.axis)
        if p == 1:
            # self-neighbour: periodic wrap is the identity exchange
            sends.append(((s.axis, "-"), [_face(x, s.dim, lo=False, width=s.halo)], s.axis, +1))
            sends.append(((s.axis, "+"), [_face(x, s.dim, lo=True, width=s.halo)], s.axis, -1))
            continue
        hi = _face(x, s.dim, lo=False, width=s.halo)   # travels to +1; recv as lo-halo
        lo = _face(x, s.dim, lo=True, width=s.halo)    # travels to -1; recv as hi-halo
        n_chunks = chunks if schedule == "chunked" else 1
        sends.append(((s.axis, "-"), _split_chunks(hi, n_chunks, s.dim), s.axis, +1))
        sends.append(((s.axis, "+"), _split_chunks(lo, n_chunks, s.dim), s.axis, -1))

    out: dict = {}
    dep = None
    for key, payloads, axis, direction in sends:
        p = compat.axis_size(axis)
        perm = ring_perm(p, direction)
        if schedule == "sequential" and dep is not None:
            payloads = _seq_token(dep, payloads)
        received = [lax.ppermute(c, axis, perm) for c in payloads]
        if schedule == "sequential":
            dep = received[-1].reshape(-1)[0]
        face = received[0] if len(received) == 1 else _reassemble(received, key, specs)
        out[key] = face
    return out


def _reassemble(parts: list[jax.Array], key, specs) -> jax.Array:
    spec = next(s for s in specs if s.axis == key[0])
    split_dim = max((d for d in range(parts[0].ndim) if d != spec.dim),
                    key=lambda d: parts[0].shape[d], default=spec.dim)
    return jnp.concatenate(parts, axis=split_dim)


def pad_with_halos(x: jax.Array, halos: dict, spec: HaloSpec) -> jax.Array:
    """Concatenate received halos onto ``x`` along ``spec.dim``."""
    lo = halos[(spec.axis, "-")]
    hi = halos[(spec.axis, "+")]
    return jnp.concatenate([lo, x, hi], axis=spec.dim)


def halo_bytes(x_shape: Sequence[int], specs: Sequence[HaloSpec], itemsize: int) -> int:
    """Bidirectional bytes injected per device per exchange (analysis)."""
    total = 0
    for s in specs:
        face = 1
        for d, n in enumerate(x_shape):
            face *= s.halo if d == s.dim else n
        total += 2 * face * itemsize
    return total

"""Paged KV cache: the serving generalisation of the huge-page arena.

A **KV page** holds one layer's K and V blocks for ``page_tokens`` token
positions of one sequence.  All pages are laid out in a single flat arena by
:func:`repro.mem.layout.plan_arena` — the same page-quantized placement the
gradient :class:`~repro.mem.arena.CommArena` uses, so every page starts on a
``page_bytes`` boundary (the paper's 2 MiB huge-page granule) and the
padding/waste accounting (:attr:`~repro.mem.layout.ArenaLayout
.padding_fraction`) comes for free.  The arena is allocated **once** and
threaded through the jitted decode step as a **donated** buffer, exactly
like the training arena: no per-step transient KV allocations, XLA aliases
input to output.

In-page element layout (cache dtype, default bf16)::

    [ K: (Hkv, page_tokens, head_dim) ][ V: same ][ page padding ]

Host-side ownership is a free-list :class:`KVPageAllocator` plus a
per-sequence :class:`PageTable` — ``table[slot, block, layer]`` is the page
id backing token positions ``[block*page_tokens, (block+1)*page_tokens)``
of ``slot`` at ``layer`` (``-1`` = unmapped).  The table is a fixed-shape
int32 array, so admission/eviction between decode steps never recompiles.

``max_blocks`` is padded up to a multiple of the mesh's model-axis size:
the paged engine dedicates the model axis to **page-parallel decode** (each
rank scores a static chunk of the block columns), so the column dim must
tile the axis.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.mem.layout import PAGE_BYTES, ArenaLayout, plan_arena


def kv_page_payload_elems(cfg: ModelConfig, page_tokens: int) -> int:
    """Used elements of one KV page: K + V for one layer's page_tokens."""
    a = cfg.attn
    return 2 * a.num_kv_heads * page_tokens * a.head_dim


def _require_pageable(cfg: ModelConfig) -> None:
    """Paged decode covers decoder-only, all-global-attention transformers.

    Rolling window/chunk caches reuse slots out of order (their validity
    mask depends on the wrap position), which a page table keyed by
    absolute block index cannot express; SSM/hybrid carry non-KV decode
    state.  Every unsupported family fails loudly here, at plan time.
    """
    if cfg.attn is None or cfg.family not in ("dense", "moe") \
            or cfg.frontend is not None or cfg.enc_layers:
        raise NotImplementedError(
            f"paged KV serving is decoder-only (family={cfg.family!r}, "
            f"frontend={cfg.frontend!r})")
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind["mixer"] != "attn" or not kind.get("attn_global", True):
            raise NotImplementedError(
                f"paged KV serving needs global attention at every layer; "
                f"layer {i} is {kind['mixer']}/local (window={cfg.attn.window}, "
                f"chunk={cfg.attn.chunk})")


@dataclass(frozen=True)
class KVArenaPlan:
    """Placement of a serving fleet's KV pages in one flat donated arena."""

    layout: ArenaLayout          # one segment per KV page, equal sizes
    page_tokens: int             # token positions per page
    max_seqs: int                # sequence slots the arena was sized for
    max_blocks: int              # page-table columns (model-axis padded)
    n_layers: int
    num_kv_heads: int
    head_dim: int
    model_parallel: int          # model-axis size the block dim tiles

    # -- shape ---------------------------------------------------------------

    @property
    def n_kv_pages(self) -> int:
        """Allocatable KV pages (arena segments)."""
        return self.layout.n_segments

    @property
    def page_stride(self) -> int:
        """Element stride between consecutive pages (page-quantized)."""
        return self.layout.segments[0].padded if self.layout.segments else 0

    @property
    def payload_elems(self) -> int:
        return self.layout.segments[0].size if self.layout.segments else 0

    @property
    def k_offset(self) -> int:
        return 0

    @property
    def v_offset(self) -> int:
        return self.num_kv_heads * self.page_tokens * self.head_dim

    @property
    def total_elems(self) -> int:
        return self.layout.total_elems

    @property
    def total_bytes(self) -> int:
        return self.layout.total_bytes

    @property
    def n_arena_pages(self) -> int:
        """Whole ``page_bytes`` allocation granules (huge pages)."""
        return self.layout.n_pages

    @property
    def padding_fraction(self) -> float:
        return self.layout.padding_fraction

    @property
    def blocks_per_rank(self) -> int:
        return self.max_blocks // self.model_parallel

    def page_offset(self, page_id: int) -> int:
        return self.layout.segments[page_id].offset

    def zeros(self) -> jnp.ndarray:
        """The allocate-once donated arena buffer (thread it through the
        jitted step; never reallocate per token)."""
        return jnp.zeros((self.total_elems,), self.layout.dtype)

    def describe(self) -> dict:
        return {
            "page_tokens": self.page_tokens,
            "max_seqs": self.max_seqs,
            "max_blocks": self.max_blocks,
            "n_layers": self.n_layers,
            "num_kv_heads": self.num_kv_heads,
            "head_dim": self.head_dim,
            "model_parallel": self.model_parallel,
            "n_kv_pages": self.n_kv_pages,
            "page_stride": self.page_stride,
            "payload_elems": self.payload_elems,
            "total_bytes": self.total_bytes,
            "n_arena_pages": self.n_arena_pages,
            "page_bytes": self.layout.page_bytes,
            "padding_fraction": self.padding_fraction,
            "dtype": jnp.dtype(self.layout.dtype).name,
        }


def plan_kv_arena(cfg: ModelConfig, mesh: Mesh | None = None, *,
                  page_tokens: int = 16, page_bytes: int = PAGE_BYTES,
                  max_seqs: int = 8, max_seq_len: int = 256,
                  cache_dtype=jnp.bfloat16) -> KVArenaPlan:
    """Page-quantized KV arena for up to ``max_seqs`` concurrent sequences
    of up to ``max_seq_len`` tokens.

    Sizing: ``max_seqs * ceil(max_seq_len / page_tokens) * num_layers``
    pages, each the page-aligned slot of one layer's K+V block — the same
    :func:`~repro.mem.layout.plan_arena` placement the gradient arena uses
    (``channel_of = 0`` everywhere: the KV arena is one contiguous span;
    page granularity, not span fusing, is what serving reuses).
    """
    _require_pageable(cfg)
    if page_tokens < 1:
        raise ValueError(f"page_tokens must be >= 1, got {page_tokens}")
    if max_seqs < 1 or max_seq_len < 1:
        raise ValueError(f"max_seqs/max_seq_len must be >= 1, got "
                         f"{max_seqs}/{max_seq_len}")
    mp = 1
    if mesh is not None:
        mp = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    blocks = math.ceil(max_seq_len / page_tokens)
    max_blocks = math.ceil(blocks / mp) * mp          # tile the model axis
    n_pages = max_seqs * max_blocks * cfg.num_layers
    payload = kv_page_payload_elems(cfg, page_tokens)
    layout = plan_arena([payload] * n_pages, page_bytes=page_bytes,
                        dtype=cache_dtype, channel_of=[0] * n_pages)
    return KVArenaPlan(layout=layout, page_tokens=page_tokens,
                       max_seqs=max_seqs, max_blocks=max_blocks,
                       n_layers=cfg.num_layers,
                       num_kv_heads=cfg.attn.num_kv_heads,
                       head_dim=cfg.attn.head_dim, model_parallel=mp)


class KVPageAllocator:
    """LIFO free-list over the arena's KV pages.

    Host-side (numpy ints, no tracing): the scheduler allocates on block
    crossings and recycles on retirement, between jitted decode steps.
    Invariants (pinned by the property tests): a page is never handed out
    twice, ``free`` of a page not currently allocated raises, and
    ``n_free + n_allocated == n_total`` across any alloc/free cycle.
    """

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"n_pages must be >= 1, got {n_pages}")
        self.n_total = int(n_pages)
        self._free: list[int] = list(range(n_pages - 1, -1, -1))
        self._allocated: set[int] = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return len(self._allocated)

    def alloc(self, n: int) -> list[int]:
        """``n`` page ids, or raises if the arena is out of pages (callers
        check :attr:`n_free` first; the scheduler queues instead)."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise MemoryError(f"KV arena out of pages: want {n}, "
                              f"free {len(self._free)}/{self.n_total}")
        out = [self._free.pop() for _ in range(n)]
        self._allocated.update(out)
        return out

    def free(self, pages) -> None:
        for p in pages:
            p = int(p)
            if p not in self._allocated:
                raise ValueError(f"page {p} is not allocated "
                                 f"(double free or foreign id)")
            self._allocated.remove(p)
            self._free.append(p)


class PageTable:
    """Fixed-shape ``(slots, max_blocks, n_layers)`` int32 page map.

    ``-1`` marks an unmapped block; the device-side gather clips ids and
    masks those positions invalid, so a partially filled table is always
    safe to hand to the jitted step.
    """

    def __init__(self, slots: int, max_blocks: int, n_layers: int):
        self.table = np.full((slots, max_blocks, n_layers), -1, np.int32)

    def map_block(self, slot: int, block: int, layer_pages) -> None:
        """Back ``(slot, block)`` with one page per layer."""
        if len(layer_pages) != self.table.shape[2]:
            raise ValueError(f"need {self.table.shape[2]} pages (one per "
                             f"layer), got {len(layer_pages)}")
        if (self.table[slot, block] >= 0).any():
            raise ValueError(f"slot {slot} block {block} already mapped")
        self.table[slot, block] = np.asarray(layer_pages, np.int32)

    def clear_slot(self, slot: int) -> list[int]:
        """Unmap every block of ``slot``; returns the freed page ids."""
        pages = self.table[slot][self.table[slot] >= 0].tolist()
        self.table[slot] = -1
        return pages

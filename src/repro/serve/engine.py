"""Paged decode engine: flash-decode attention over the KV page arena.

One jitted step decodes one token for every active slot against the paged
KV cache.  The design commitments, in paper terms:

* **One donated buffer.**  The whole KV cache is the flat page arena from
  :func:`repro.serve.kv.plan_kv_arena`; it is the step's *first* argument
  and is donated, so XLA aliases input to output and the buffer is
  allocated exactly once for the life of the server — the serving analogue
  of the gradient :class:`~repro.mem.arena.CommArena`.
* **Page-parallel decode on the model axis.**  Weights replicate across the
  model axis (decode is α-bound, not FLOP-bound; head-sharding would force
  a collective per projection) and the axis is spent where the memory is:
  each rank gathers and scores a static ``blocks_per_rank`` chunk of the
  page-table columns with the split-KV flash-decode kernel, then the
  partial softmax statistics merge across ranks.
* **Two collectives per layer per token, fused.**  The cross-rank merge is
  one ``pmax`` of the running max plus ONE fused
  :meth:`Communicator.all_reduce` carrying the rescaled numerator and
  denominator in a single flat buffer — against the naive three
  (max/num/den) of the sequence-sharded path in ``models.attention``.
  With ``model == 1`` both are statically skipped: a single-rank decode
  step lowers to **zero** collectives.  ``dryrun --suite serve`` holds the
  resulting count (``2 · n_layers`` or ``0``) to the optimized HLO exactly.

Admission, eviction and page recycling are host-side (numpy) and change no
traced shape, so the step compiles once per ``(plan, arch)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.comm import CommConfig, Communicator
from repro.obs import NULL_OBS
from repro.configs.base import ModelConfig
from repro.kernels.flash_decode import ops as fd_ops
from repro.kernels.flash_decode import ref as fd_ref
from repro.models import moe as moe_mod
from repro.models.attention import _merge_heads, _split_heads, padded_heads
from repro.models.common import (apply_rope, dense, embed, glu_mlp, rmsnorm,
                                 unembed)
from repro.runtime.train_step import make_ctx
from repro.serve.kv import KVArenaPlan, KVPageAllocator, PageTable
from repro.sharding import rules as shard_rules


# ---------------------------------------------------------------------------
# prediction layer (read by launch/dryrun --suite serve and bench_serve)
# ---------------------------------------------------------------------------


def predicted_collectives_per_token(plan: KVArenaPlan) -> int:
    """HLO all-reduce ops one decode step lowers to: pmax + one fused LSE
    stats reduce per layer when the model axis is real, else zero."""
    return 2 * plan.n_layers if plan.model_parallel > 1 else 0


def predicted_wire_bytes_per_token(plan: KVArenaPlan, cfg: ModelConfig,
                                   batch: int) -> float:
    """Per-device all-reduce wire bytes of one decode step (ring lower
    bound, ``2(R-1)/R`` hops): the fp32 running max (B·Hq) plus the fused
    numerator+denominator buffer (B·Hq·(D+1)) per layer."""
    r = plan.model_parallel
    if r <= 1:
        return 0.0
    hq = padded_heads(cfg.attn.num_heads)
    hops = 2.0 * (r - 1) / r
    per_layer = (batch * hq + batch * hq * (plan.head_dim + 1)) * 4
    return plan.n_layers * per_layer * hops


# ---------------------------------------------------------------------------
# paged read/write (device side, fixed shapes)
# ---------------------------------------------------------------------------


def _write_token_kv(pages, plan: KVArenaPlan, layer: int, table, slot_len,
                    slot_valid, k1, v1):
    """Scatter this step's K/V (B, Hkv, 1, D) into each slot's current page.

    Invalid slots (or unmapped blocks) get an out-of-bounds index, which the
    scatter drops — no branch, no shape change."""
    pt, d, hkv = plan.page_tokens, plan.head_dim, plan.num_kv_heads
    block = slot_len // pt
    within = slot_len % pt
    page = jnp.take_along_axis(table[:, :, layer], block[:, None],
                               axis=1)[:, 0]                       # (B,)
    ok = slot_valid & (page >= 0)
    base = page * plan.page_stride + within * d                    # (B,)
    idx = (base[:, None, None]
           + (jnp.arange(hkv) * (pt * d))[None, :, None]
           + jnp.arange(d)[None, None, :])                         # (B,Hkv,D)
    idx = jnp.where(ok[:, None, None], idx, plan.total_elems)      # OOB drop
    pages = pages.at[idx].set(k1[:, :, 0, :].astype(pages.dtype))
    pages = pages.at[idx + plan.v_offset].set(v1[:, :, 0, :].astype(pages.dtype))
    return pages


def _gather_local_kv(pages, plan: KVArenaPlan, layer: int, table, rank):
    """This rank's chunk of the paged cache as dense (B, Hkv, L_local, D)
    K/V, plus its page-table slice (for validity).  ``rank`` is traced;
    the chunk extent ``blocks_per_rank`` is static."""
    bpr, pt, d = plan.blocks_per_rank, plan.page_tokens, plan.head_dim
    hkv = plan.num_kv_heads
    tab = lax.dynamic_slice_in_dim(table[:, :, layer], rank * bpr, bpr,
                                   axis=1)                         # (B, bpr)
    base = jnp.maximum(tab, 0) * plan.page_stride
    off = ((jnp.arange(hkv) * (pt * d))[:, None, None]
           + (jnp.arange(pt) * d)[None, :, None]
           + jnp.arange(d)[None, None, :])                     # (Hkv, Pt, D)
    idx = base[:, :, None, None, None] + off[None, None]   # (B,bpr,Hkv,Pt,D)
    b = idx.shape[0]
    k = jnp.take(pages, idx).transpose(0, 2, 1, 3, 4) \
        .reshape(b, hkv, bpr * pt, d)
    v = jnp.take(pages, idx + plan.v_offset).transpose(0, 2, 1, 3, 4) \
        .reshape(b, hkv, bpr * pt, d)
    return k, v, tab


def _local_valid(plan: KVArenaPlan, tab, slot_len, slot_valid, rank):
    """(B, L_local) mask: position exists (≤ current pos, incl. the token
    just written), its block is mapped, and the slot is live."""
    bpr, pt = plan.blocks_per_rank, plan.page_tokens
    blk = rank * bpr + jnp.arange(bpr)
    gpos = blk[:, None] * pt + jnp.arange(pt)[None, :]         # (bpr, Pt)
    ok = gpos[None] <= slot_len[:, None, None]
    ok = ok & (tab >= 0)[:, :, None] & slot_valid[:, None, None]
    return ok.reshape(ok.shape[0], bpr * pt)


# ---------------------------------------------------------------------------
# the step
# ---------------------------------------------------------------------------


def build_paged_decode_step(model, mesh: Mesh, plan: KVArenaPlan, *,
                            attn_impl: str = "kernel",
                            interpret: bool | None = None,
                            donate: bool = True):
    """Returns ``(step, param_specs, state_specs)`` with
    ``step(pages, params, table, token, slot_len, slot_valid) ->
    (logits (B, vocab), pages)``; ``pages`` is donated (argument 0).

    ``params`` must be the full (un-sharded) tree — the engine replicates
    weights over the model axis by design (see module docstring).
    ``attn_impl``: "kernel" scores pages with the Pallas flash-decode
    kernel, "ref" with the jnp oracle (same math and identical collective
    footprint; the dry-run uses "ref" to keep compile times sane).
    """
    if attn_impl not in ("kernel", "ref"):
        raise ValueError(f"attn_impl must be kernel|ref, got {attn_impl!r}")
    cfg = model.cfg
    ctx = make_ctx(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    r_mesh = sizes.get("model", 1)
    if r_mesh != plan.model_parallel:
        raise ValueError(
            f"plan was laid out for model_parallel={plan.model_parallel} "
            f"but the mesh model axis is {r_mesh}; re-plan with this mesh")
    r = plan.model_parallel
    comm = (Communicator(mesh, CommConfig(transport="psum",
                                          data_axes=("model",), channels=1))
            if r > 1 else None)
    cdt = jnp.dtype(cfg.dtype)
    hkv, hd = cfg.attn.num_kv_heads, cfg.attn.head_dim
    true_group = max(cfg.attn.num_heads // hkv, 1)

    def attend(q, pages, layer, table, slot_len, slot_valid):
        k, v, tab = _gather_local_kv(pages, plan, layer, table,
                                     ctx.model_index())
        # true-group GQA map (padded q heads clip to the last kv head) —
        # expand kv per q head so the kernel runs group-free; the uniform
        # h//group map inside the kernel would mis-pair padded head counts.
        kv_idx = jnp.clip(jnp.arange(q.shape[1]) // true_group, 0, hkv - 1)
        k = jnp.take(k, kv_idx, axis=1)
        v = jnp.take(v, kv_idx, axis=1)
        valid = _local_valid(plan, tab, slot_len, slot_valid,
                             ctx.model_index())
        if attn_impl == "kernel":
            acc, m, l = fd_ops.flash_decode_stats(q, k, v, valid,
                                                  interpret=interpret)
        else:
            acc, m, l = fd_ref.decode_stats(q, k, v, valid)
        if r == 1:
            return fd_ref.combine([(acc, m, l)]).astype(q.dtype)
        m_g = ctx.pmax(m)
        w = jnp.exp(m - m_g)
        n_num = acc.size
        buf = jnp.concatenate([(acc * w).reshape(-1), (l * w).reshape(-1)])
        red = comm.all_reduce([buf])[0]
        num = red[:n_num].reshape(acc.shape)
        den = red[n_num:].reshape(l.shape)
        return (num / jnp.maximum(den, 1e-30)).astype(q.dtype)

    def fn(pages, params, table, token, slot_len, slot_valid):
        x = embed(params["embed"], token[:, None], cdt, ctx, cfg.vocab_size)
        posb = slot_len[:, None]                       # per-slot position
        for i, bp in enumerate(params["blocks"]):
            kind = cfg.layer_kind(i)
            h = rmsnorm(bp["ln1"], x, cfg.norm_eps)
            pa = bp["attn"]
            n_hq = pa["wq"]["w"].shape[1] // hd
            q = _split_heads(dense(pa["wq"], h, cdt), n_hq)
            k1 = _split_heads(dense(pa["wk"], h, cdt), hkv)
            v1 = _split_heads(dense(pa["wv"], h, cdt), hkv)
            q = apply_rope(q, posb, cfg.attn.rope_theta)
            k1 = apply_rope(k1, posb, cfg.attn.rope_theta)
            pages = _write_token_kv(pages, plan, i, table, slot_len,
                                    slot_valid, k1, v1)
            o = attend(q, pages, i, table, slot_len, slot_valid)
            x = x + dense(pa["wo"], _merge_heads(o), cdt).astype(x.dtype)
            if "moe" in bp or "mlp" in bp:
                h2 = rmsnorm(bp["ln2"], x, cfg.norm_eps)
                if kind["mlp"] == "moe":
                    y, _, _ = moe_mod.moe_apply(bp["moe"], h2, cfg.moe,
                                                cfg.act, ctx=ctx,
                                                compute_dtype=cdt)
                else:
                    y = glu_mlp(bp["mlp"], h2, cfg.act, cdt, ctx, cfg.d_ff)
                x = x + y.astype(x.dtype)
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x, cdt)
        else:
            logits = dense(params["lm_head"], x, cdt)
        return logits[:, 0], pages

    state_abs = {
        "pages": jax.ShapeDtypeStruct((plan.total_elems,), plan.layout.dtype),
        "page_table": jax.ShapeDtypeStruct(
            (plan.max_seqs, plan.max_blocks, plan.n_layers), jnp.int32),
        "slot_len": jax.ShapeDtypeStruct((plan.max_seqs,), jnp.int32),
        "slot_valid": jax.ShapeDtypeStruct((plan.max_seqs,), jnp.bool_),
    }
    sspecs = shard_rules.decode_state_specs(state_abs, cfg, mesh,
                                            plan.max_seqs)
    pspecs = jax.tree.map(lambda _: P(), model.abstract_params())
    sharded = compat.shard_map(
        fn, mesh=mesh,
        in_specs=(sspecs["pages"], pspecs, sspecs["page_table"], P(),
                  sspecs["slot_len"], sspecs["slot_valid"]),
        out_specs=(P(), sspecs["pages"]), check_vma=False)
    step = jax.jit(sharded, donate_argnums=(0,) if donate else ())
    return step, pspecs, sspecs


# ---------------------------------------------------------------------------
# host-side engine: slots, pages, one compile
# ---------------------------------------------------------------------------


class PagedDecodeEngine:
    """Slot-indexed decode over the page arena.

    Owns the donated arena buffer, the free-list allocator and the page
    table; :meth:`decode` runs one step for every live slot.  All slot
    management is host numpy with fixed traced shapes — admitting or
    retiring between steps never recompiles."""

    def __init__(self, model, mesh: Mesh, plan: KVArenaPlan, *,
                 attn_impl: str = "kernel", interpret: bool | None = None,
                 donate: bool = True, obs=None):
        self.model, self.mesh, self.plan = model, mesh, plan
        self.obs = obs if obs is not None else NULL_OBS
        self.step, self.param_specs, self.state_specs = \
            build_paged_decode_step(model, mesh, plan, attn_impl=attn_impl,
                                    interpret=interpret, donate=donate)
        self.allocator = KVPageAllocator(plan.n_kv_pages)
        self.table = PageTable(plan.max_seqs, plan.max_blocks, plan.n_layers)
        self.slot_len = np.zeros((plan.max_seqs,), np.int32)
        self.slot_valid = np.zeros((plan.max_seqs,), bool)
        self.pages = plan.zeros()

    # -- slot management (host side) ----------------------------------------

    def free_slots(self) -> list[int]:
        return [i for i in range(self.plan.max_seqs) if not self.slot_valid[i]]

    def pages_for(self, n_tokens: int) -> int:
        """Worst-case pages a sequence of ``n_tokens`` needs (all layers)."""
        import math as _m

        return _m.ceil(n_tokens / self.plan.page_tokens) * self.plan.n_layers

    def can_admit(self, n_tokens: int) -> bool:
        return (bool(self.free_slots())
                and self.allocator.n_free >= self.pages_for(n_tokens))

    def admit(self, slot: int) -> None:
        if self.slot_valid[slot]:
            raise ValueError(f"slot {slot} is already live")
        self.slot_len[slot] = 0
        self.slot_valid[slot] = True
        self._ensure_block(slot)
        self.obs.counter("admits")
        self.obs.event("admit", slot=slot,
                       pages_free=self.allocator.n_free)
        self._kv_gauges()

    def retire(self, slot: int) -> None:
        tokens = int(self.slot_len[slot])
        self.allocator.free(self.table.clear_slot(slot))
        self.slot_valid[slot] = False
        self.slot_len[slot] = 0
        self.obs.counter("retires")
        self.obs.event("retire", slot=slot, tokens=tokens,
                       pages_free=self.allocator.n_free)
        self._kv_gauges()

    def _kv_gauges(self) -> None:
        """Arena health after a slot transition: page occupancy (fraction of
        arena pages mapped) and page waste (fraction of mapped capacity not
        yet holding a token — the partial last page of every live slot)."""
        alloc, plan = self.allocator, self.plan
        used = alloc.n_total - alloc.n_free
        self.obs.gauge("kv_pages_used", used)
        self.obs.gauge("kv_pages_free", alloc.n_free)
        self.obs.gauge("kv_page_occupancy", used / max(alloc.n_total, 1))
        cap_tokens = (used // plan.n_layers) * plan.page_tokens
        held = int(self.slot_len[self.slot_valid].sum())
        waste = 1.0 - held / cap_tokens if cap_tokens else 0.0
        self.obs.gauge("kv_page_waste", waste)
        self.obs.gauge("live_slots", int(self.slot_valid.sum()))

    def _ensure_block(self, slot: int) -> None:
        blk = int(self.slot_len[slot]) // self.plan.page_tokens
        if self.table.table[slot, blk, 0] < 0:
            self.table.map_block(slot, blk,
                                 self.allocator.alloc(self.plan.n_layers))

    # -- the hot loop --------------------------------------------------------

    def decode(self, params, token) -> jax.Array:
        """One decode step: write ``token[slot]`` at each live slot's
        position, attend over its pages, return logits (B, vocab).
        Invalid slots' rows are garbage by contract."""
        for s in np.nonzero(self.slot_valid)[0]:
            self._ensure_block(int(s))
        with self.mesh:
            logits, self.pages = self.step(
                self.pages, params, jnp.asarray(self.table.table),
                jnp.asarray(token, jnp.int32).reshape(self.plan.max_seqs),
                jnp.asarray(self.slot_len), jnp.asarray(self.slot_valid))
        self.slot_len[self.slot_valid] += 1
        return logits

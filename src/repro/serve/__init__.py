"""repro.serve: continuous-batching inference over a paged KV-cache arena.

The serving-side incarnation of the paper's huge-page pillar: decode is the
α-dominated regime (per-token collectives with tiny payloads), so the KV
cache lives in one persistent, donated, page-quantized arena
(:mod:`repro.serve.kv` generalises :class:`repro.mem.layout.ArenaLayout`
into a page table), requests are admitted/evicted in-flight between decode
steps without recompilation (:mod:`repro.serve.scheduler`), and attention
over the paged cache runs as a split-KV flash-decode whose partial softmax
statistics combine across the model axis through the channelized
:class:`repro.comm.Communicator` (:mod:`repro.serve.engine` +
:mod:`repro.kernels.flash_decode`).
"""

from repro.serve.engine import PagedDecodeEngine, build_paged_decode_step
from repro.serve.kv import KVArenaPlan, KVPageAllocator, plan_kv_arena
from repro.serve.scheduler import Request, ServeScheduler, mixed_trace

__all__ = ["KVArenaPlan", "KVPageAllocator", "plan_kv_arena",
           "PagedDecodeEngine", "build_paged_decode_step",
           "Request", "ServeScheduler", "mixed_trace"]

"""Continuous batching over the paged decode engine.

The scheduler is pure host logic between jitted decode steps: admit
requests from a FIFO queue into free slots (allocating their first pages),
stream prompt tokens through the decode path one per step (chunked prefill,
width 1 — one compiled program for prefill and decode), and retire finished
sequences immediately, recycling their pages for the next request in the
queue.  Traced shapes never change, so nothing recompiles.

Two policies make the paper-style A/B measurable in ``bench_serve``:

* ``continuous`` — admit whenever a slot and pages are free (in-flight
  batching).  A finished short request's slot turns around on the next
  step even while a long request keeps decoding.
* ``static`` — the classic baseline: admit a full batch only when *every*
  slot is free, then run until the whole batch finishes.  One long
  sequence holds the other slots hostage; on a mixed-length trace this is
  the ≥ 2× throughput gap the acceptance bar asks for.

Accounting: a request needs ``prompt_len + decode_len - 1`` steps (the step
feeding the last prompt token yields the first generated token); every step
at or past the prompt produces one token.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.obs import NULL_OBS


@dataclass(frozen=True)
class Request:
    rid: int
    prompt_len: int
    decode_len: int

    def __post_init__(self):
        if self.prompt_len < 1 or self.decode_len < 1:
            raise ValueError(f"request {self.rid}: prompt_len and decode_len "
                             f"must be >= 1")

    @property
    def total_steps(self) -> int:
        return self.prompt_len + self.decode_len - 1

    @property
    def total_tokens(self) -> int:
        """KV positions the request occupies (sizing / can_admit)."""
        return self.prompt_len + self.decode_len


def mixed_trace(groups: int = 4, slots: int = 4, long_len: int = 64,
                short_len: int = 4, prompt_len: int = 1) -> list[Request]:
    """Mixed-length synthetic trace: each group is one long request followed
    by ``slots - 1`` short ones, so a static batch is forced to pair every
    long sequence with shorts it will hold hostage."""
    reqs: list[Request] = []
    rid = 0
    for _ in range(groups):
        reqs.append(Request(rid, prompt_len, long_len))
        rid += 1
        for _ in range(slots - 1):
            reqs.append(Request(rid, prompt_len, short_len))
            rid += 1
    return reqs


class ServeScheduler:
    """Drives a :class:`~repro.serve.engine.PagedDecodeEngine` over a
    request trace under one of the two batching policies."""

    def __init__(self, engine, policy: str = "continuous", obs=None):
        if policy not in ("continuous", "static"):
            raise ValueError(f"policy must be continuous|static, "
                             f"got {policy!r}")
        self.engine = engine
        self.policy = policy
        # default to the engine's obs so one handle instruments the pair
        self.obs = obs if obs is not None \
            else getattr(engine, "obs", NULL_OBS)

    def _admit(self, queue: deque, slot_req: list, fed: np.ndarray) -> None:
        eng = self.engine
        if self.policy == "static" and eng.slot_valid.any():
            return                      # static: wait for the whole batch
        while queue and eng.can_admit(queue[0].total_tokens) :
            req = queue.popleft()
            slot = eng.free_slots()[0]
            eng.admit(slot)
            slot_req[slot] = req
            fed[slot] = 0

    def run(self, params, requests: list[Request], *,
            max_steps: int = 100_000) -> dict:
        """Process every request; returns throughput stats (tokens are
        *generated* tokens — prompt streaming is overhead, not output)."""
        eng = self.engine
        obs = self.obs
        vocab = eng.model.cfg.vocab_size
        s = eng.plan.max_seqs
        queue = deque(requests)
        slot_req: list[Request | None] = [None] * s
        fed = np.zeros((s,), np.int64)
        generated = np.zeros((s,), np.int64)
        steps = total_generated = total_prefill = 0
        live_sum = 0
        t_run = time.time()

        while queue or eng.slot_valid.any():
            self._admit(queue, slot_req, fed)
            obs.gauge("queue_depth", len(queue), policy=self.policy)
            live = np.nonzero(eng.slot_valid)[0]
            if live.size == 0:
                obs.counter("serve_stall", reason="arena_too_small")
                obs.event("serve_stall", reason="arena_too_small",
                          queued=len(queue),
                          need_tokens=queue[0].total_tokens,
                          pages_free=eng.allocator.n_free,
                          pages_total=eng.allocator.n_total)
                raise RuntimeError(
                    f"scheduler stalled with {len(queue)} queued requests: "
                    f"request needs {queue[0].total_tokens} tokens but the "
                    f"arena cannot ever fit it (free pages "
                    f"{eng.allocator.n_free}/{eng.allocator.n_total})")
            if steps >= max_steps:
                obs.counter("serve_stall", reason="max_steps")
                obs.event("serve_stall", reason="max_steps",
                          max_steps=max_steps, queued=len(queue))
                raise RuntimeError(f"exceeded max_steps={max_steps}")
            # deterministic synthetic token stream (rid-keyed): the engine's
            # numerics are pinned elsewhere; the scheduler measures steps
            token = np.zeros((s,), np.int32)
            for sl in live:
                r = slot_req[sl]
                token[sl] = (r.rid * 7 + int(fed[sl])) % vocab
            with obs.span("decode_step", policy=self.policy):
                eng.decode(params, token)
            steps += 1
            live_sum += int(live.size)
            for sl in live:
                r = slot_req[sl]
                fed[sl] += 1
                if fed[sl] >= r.prompt_len:
                    generated[sl] += 1
                    total_generated += 1
                else:
                    total_prefill += 1
                if generated[sl] >= r.decode_len:
                    eng.retire(int(sl))
                    slot_req[sl] = None
                    generated[sl] = 0

        wall = time.time() - t_run
        obs.gauge("tokens_per_step", total_generated / max(steps, 1),
                  policy=self.policy)
        obs.gauge("tokens_per_sec", total_generated / max(wall, 1e-9),
                  policy=self.policy)
        obs.gauge("mean_live_slots", live_sum / max(steps, 1),
                  policy=self.policy)
        obs.event("serve_done", policy=self.policy, steps=steps,
                  generated_tokens=total_generated, wall_s=wall,
                  n_requests=len(requests))
        return {
            "policy": self.policy,
            "n_requests": len(requests),
            "steps": steps,
            "generated_tokens": total_generated,
            "prefill_steps": total_prefill,
            "tokens_per_step": total_generated / max(steps, 1),
            "mean_live_slots": live_sum / max(steps, 1),
        }
